//! Per-shard incremental re-scoring against the newest λ generation.
//!
//! Corpus shards stream into a [`ShardStore`]; a background rescorer
//! (spawned by [`super::ServeSession`]) keeps every shard's cached prune
//! scores fresh against the hub's newest snapshot and reports staleness —
//! generations behind and seconds behind — per shard. Scoring itself goes
//! through the [`SnapshotScorer`] trait so the batch pruning path and the
//! online serving path share one kernel
//! (see `apps::pruning::snapshot_scores`).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::snapshot::{LambdaSnapshot, SnapshotHub};
use crate::data::corpus::CorpusShard;

/// Scores corpus rows against one published λ snapshot.
///
/// Implementations must be pure functions of `(snap.lambda, features)`:
/// the serving contract (invariant 10) is that a query pinned to
/// generation g returns bitwise the same scores as a batch run stopped at
/// g's cut, which only holds if the scorer has no hidden state.
pub trait SnapshotScorer: Send + Sync {
    fn score_rows(
        &self,
        snap: &LambdaSnapshot,
        shard: &CorpusShard,
        rows: &[usize],
    ) -> Vec<f32>;
}

/// End-of-pass freshness of one shard's cached scores.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardStaleness {
    pub shard: u64,
    pub rows: usize,
    /// Generation the cached scores were computed against (0 = never).
    pub scored_generation: u64,
    /// Newest published generation minus `scored_generation`.
    pub generations_behind: u64,
    /// Seconds since the cached scores were (re)computed — since ingest
    /// if the shard has never been scored; 0.0 when fully fresh.
    pub seconds_behind: f64,
}

struct ShardEntry {
    shard: Arc<CorpusShard>,
    scores: Vec<f32>,
    scored_gen: u64,
    scored_step: u64,
    ingested_at: Instant,
    scored_at: Option<Instant>,
}

/// Streamed corpus shards plus their incrementally-refreshed score cache.
/// `BTreeMap` keyed by shard id: deterministic iteration order for
/// rescore passes and staleness reports.
#[derive(Default)]
pub struct ShardStore {
    inner: Mutex<BTreeMap<u64, ShardEntry>>,
}

impl ShardStore {
    pub fn new() -> ShardStore {
        ShardStore::default()
    }

    /// Stream one shard in. Re-ingesting an id replaces the shard and
    /// invalidates its cached scores (content may have changed).
    pub fn ingest(&self, shard: CorpusShard) {
        let entry = ShardEntry {
            scores: Vec::new(),
            scored_gen: 0,
            scored_step: 0,
            ingested_at: Instant::now(),
            scored_at: None,
            shard: Arc::new(shard),
        };
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(entry.shard.id, entry);
    }

    pub fn shard(&self, id: u64) -> Option<Arc<CorpusShard>> {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id)
            .map(|e| Arc::clone(&e.shard))
    }

    pub fn ids(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .copied()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The cached scores and the generation they were computed against
    /// (None until the rescorer's first pass over this shard).
    pub fn cached_scores(&self, id: u64) -> Option<(Vec<f32>, u64)> {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id)
            .filter(|e| e.scored_gen > 0)
            .map(|e| (e.scores.clone(), e.scored_gen))
    }

    /// One incremental pass: re-score every shard that is behind the
    /// hub's newest snapshot. Scoring runs outside the store lock (a
    /// pass over a large shard must not block `ingest`/lookups); the
    /// write-back re-checks the generation so a concurrent newer pass is
    /// never clobbered by an older one. Returns shards refreshed.
    pub fn rescore_pass(
        &self,
        hub: &SnapshotHub,
        scorer: &dyn SnapshotScorer,
    ) -> usize {
        let snap = hub.load();
        if snap.generation == 0 {
            return 0;
        }
        let stale: Vec<Arc<CorpusShard>> = self
            .inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .filter(|e| e.scored_gen < snap.generation)
            .map(|e| Arc::clone(&e.shard))
            .collect();
        let mut refreshed = 0usize;
        for shard in stale {
            let rows: Vec<usize> = (0..shard.rows()).collect();
            let scores = scorer.score_rows(&snap, &shard, &rows);
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(e) = inner.get_mut(&shard.id) {
                if e.scored_gen < snap.generation
                    && Arc::ptr_eq(&e.shard, &shard)
                {
                    e.scores = scores;
                    e.scored_gen = snap.generation;
                    e.scored_step = snap.step;
                    e.scored_at = Some(Instant::now());
                    refreshed += 1;
                }
            }
        }
        refreshed
    }

    /// Per-shard staleness versus the hub's newest generation.
    pub fn staleness(&self, hub: &SnapshotHub) -> Vec<ShardStaleness> {
        let newest = hub.generation();
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .map(|e| {
                let behind = newest.saturating_sub(e.scored_gen);
                let seconds = if behind == 0 && e.scored_gen > 0 {
                    0.0
                } else {
                    e.scored_at
                        .unwrap_or(e.ingested_at)
                        .elapsed()
                        .as_secs_f64()
                };
                ShardStaleness {
                    shard: e.shard.id,
                    rows: e.shard.rows(),
                    scored_generation: e.scored_gen,
                    generations_behind: behind,
                    seconds_behind: seconds,
                }
            })
            .collect()
    }

    /// Worst-case generations-behind across all shards (0 when every
    /// shard is fresh — the rescorer's convergence predicate).
    pub fn max_generations_behind(&self, hub: &SnapshotHub) -> u64 {
        self.staleness(hub)
            .iter()
            .map(|s| s.generations_behind)
            .max()
            .unwrap_or(0)
    }
}
