//! detlint fixture — `allow` directives, well-formed.
//!
//! Each allow names a real rule and carries a reason, so every seeded
//! violation below is an enumerated, justified exception — and the file
//! scans clean.

use std::time::Instant;

pub struct DebugCache {
    // detlint: allow(nondet-iteration) — debug-only hit counters, keyed
    // lookups; iteration order never reaches a reduce, a route, or a blob
    pub hits: std::collections::HashMap<String, u64>,
}

/// Attribution-only stamp, off every decision path.
pub fn stamp() -> Instant {
    Instant::now() // detlint: allow(wallclock-in-decision) — metrics attribution only
}

/// Wire-compat shim for the v0 header layout.
pub fn legacy_ring(idx: u64, rings: u64) -> u64 {
    idx % rings // detlint: allow(route-outside-scheduler) — frozen v0 wire layout; live routes go through the scheduler
}
