//! detlint fixture — `shard-outside-partition`, known-bad.
//!
//! Shard ownership re-derived outside `collective::owned_ranges`: the
//! copy agrees with the chokepoint today, and the first time either side
//! changes (tail handling, bucket tiling, owner rotation) two ranks both
//! claim — or neither claims — the same m/v slice, and the all-gather
//! re-replicates divergent θ.

/// A hand-rolled copy of the chokepoint's chunk partition.
pub fn my_chunk(chunk: usize, n: usize, world: usize) -> (usize, usize) {
    let base = n / world; //~ shard-outside-partition
    let rem = n % world; //~ shard-outside-partition
    (chunk * base + chunk.min(rem), base + usize::from(chunk < rem))
}

/// Owner rotation duplicated from the ring engine.
pub fn my_owner(rank: usize, shard_world: usize) -> usize {
    (rank + 1) % shard_world.max(1) //~ shard-outside-partition
}

/// The method-call shape: partitioning by a live collective's world.
pub struct Coll {
    world: usize,
}

impl Coll {
    pub fn world(&self) -> usize {
        self.world
    }
}

pub fn my_stride(n: usize, coll: &Coll) -> usize {
    n / coll.world() //~ shard-outside-partition
}
