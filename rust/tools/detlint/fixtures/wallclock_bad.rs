//! detlint fixture — `wallclock-in-decision`, known-bad.
//!
//! Wall clock is the canonical rank-divergent input: two ranks reading
//! their own clocks and branching on the result route differently, and
//! the collective deadlocks or silently diverges.

use std::time::{Instant, SystemTime}; //~ wallclock-in-decision

/// Routes to the "fast" ring when the last reduce felt slow — felt slow
/// *on this rank*, so ranks disagree.
pub fn pick_ring(last_reduce_started: Instant, rings: usize) -> usize {
    let elapsed = last_reduce_started.elapsed();
    let now = Instant::now(); //~ wallclock-in-decision
    let _ = now;
    if elapsed.as_millis() > 5 {
        0
    } else {
        rings - 1
    }
}

/// Epoch-stamps a retune decision: every rank stamps a different epoch.
pub fn retune_epoch() -> u64 {
    SystemTime::now() //~ wallclock-in-decision
        .duration_since(SystemTime::UNIX_EPOCH) //~ wallclock-in-decision
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
