//! detlint fixture — `compress-ctrl-tag`, known-bad.
//!
//! A lossy codec applied to the Ctrl stream: Ctrl reduces carry the
//! rank-averaged profile sums every rank must agree on bitwise before it
//! retunes routing. Quantizing them hands each rank slightly different
//! numbers to retune from — the decisions desynchronize.

pub enum ReduceTag {
    Theta,
    Lambda,
    Ctrl,
}

pub enum Codec {
    None,
    F16,
}

pub fn codec_for(_tag: &ReduceTag) -> Codec {
    Codec::F16
}

pub fn quantize_ef(_codec: Codec, _data: &mut [f32], _res: &mut [f32]) {}

/// Compressing the control sums directly.
pub fn submit_ctrl(sums: &mut [f32], res: &mut [f32]) {
    quantize_ef(codec_for(&ReduceTag::Ctrl), sums, res); //~ compress-ctrl-tag
}

/// Re-deciding the codec per tag at a call site instead of behind the
/// policy chokepoint.
pub fn pick(tag: &ReduceTag) -> Codec {
    match tag {
        ReduceTag::Ctrl => codec_for(tag), //~ compress-ctrl-tag
        _ => Codec::None,
    }
}
