//! detlint fixture — `shard-outside-partition`, fixed.
//!
//! Shard ownership has one home: `collective::owned_ranges` (and its
//! `chunk_range`). Everyone else — the owner-shard optimizer, checkpoint
//! reassembly, elastic rebuild — asks it for `(start, len)` ranges. In
//! the real tree the chokepoint lives under `src/collective`, where the
//! rule is off by scoping; the fixture stand-in carries the allow.

/// The chokepoint stand-in (really `collective::chunk_range`).
pub fn chunk_range(c: usize, n: usize, world: usize) -> (usize, usize) {
    // detlint: allow(shard-outside-partition) — this *is* the partition
    // chokepoint; fixtures sit outside src/collective, so say so
    let (base, rem) = (n / world.max(1), n % world.max(1));
    (c * base + c.min(rem), base + usize::from(c < rem))
}

/// Everyone else derives ownership by asking the chokepoint.
pub fn owned_ranges(n: usize, world: usize) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    for c in 0..world {
        let (start, len) = chunk_range(c, n, world);
        if len > 0 {
            ranges.push((start, len));
        }
    }
    ranges
}

/// Compact shard length: sum of owned ranges, no re-partitioning.
pub fn owned_len(ranges: &[(usize, usize)]) -> usize {
    ranges.iter().map(|r| r.1).sum()
}
