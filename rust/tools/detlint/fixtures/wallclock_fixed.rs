//! detlint fixture — `wallclock-in-decision`, fixed.
//!
//! Decisions consume the Ctrl-synced profile value — already averaged
//! across ranks, identical on every rank — and raw timestamps survive
//! only on the metrics/attribution path, behind an allow that says so.

use std::time::{Duration, Instant};

/// Routing input is the *synced* reduce cost, not a local clock read:
/// every rank sees the same number, so every rank picks the same ring.
pub fn pick_ring(synced_reduce_cost: Duration, rings: usize) -> usize {
    if synced_reduce_cost.as_millis() > 5 {
        0
    } else {
        rings - 1
    }
}

/// Attribution-only stamp; the value feeds the metrics sink and nothing
/// else.
pub fn stamp_attribution() -> Instant {
    // detlint: allow(wallclock-in-decision) — attribution-only timestamp;
    // never compared or routed on, so ranks may disagree freely
    Instant::now()
}
