//! detlint fixture — `lock-across-recv`, known-bad.
//!
//! A mutex guard held across a ring rendezvous: the peer that owns the
//! next hop blocks on the lock, never reaches its own `recv()`, and the
//! ring deadlocks — with every rank reporting itself "waiting normally".

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

pub fn drain_with_guard(state: &Mutex<Vec<u64>>, rx: &Receiver<u64>) -> u64 {
    let mut pending = state.lock().expect("collective state lock poisoned");
    let word = rx.recv().expect("ring peer hung up"); //~ lock-across-recv
    pending.push(word);
    word
}

pub fn publish_with_guard(state: &Mutex<Vec<u64>>, tx: &Sender<u64>) {
    let pending = state.lock().expect("collective state lock poisoned");
    for w in pending.iter() {
        tx.send(*w).expect("ring peer hung up"); //~ lock-across-recv
    }
}
