//! detlint fixture — `float-accum-cast`, known-bad.
//!
//! The PR 1 bytes-accounting bug class: a float accumulator truncated to
//! int on every call. Each truncation loses up to one unit, the loss
//! scales with call count, and two ranks with different call counts stop
//! agreeing on "exact" totals.

pub struct Accounting {
    bytes_exact: f64,
}

impl Accounting {
    pub fn charge(&mut self, elems: usize, ratio: f64) -> u64 {
        self.bytes_exact += elems as f64 * ratio;
        self.bytes_exact as u64 //~ float-accum-cast
    }

    pub fn budget_micros(window_secs: f64) -> u64 {
        (window_secs * 1_000_000.0) as u64 //~ float-accum-cast
    }
}
