//! detlint fixture — `bad-allow`, known-bad.
//!
//! Broken directives are findings in their own right, and they suppress
//! nothing: the violation each one points at still fires. An allow is
//! load-bearing documentation; a broken one silently enforces nothing.

//~ bad-allow (the reason is mandatory) — detlint: allow(nondet-iteration)
use std::collections::HashMap; //~ nondet-iteration

// detlint: allow(nondet-map-iteration) — no such rule //~ bad-allow
use std::collections::HashSet; //~ nondet-iteration

// detlint: allowed(nondet-iteration) — `allowed(` is not `allow(` //~ bad-allow
pub type Routes = HashMap<u64, u64>; //~ nondet-iteration
