//! detlint fixture — `float-accum-cast`, fixed.
//!
//! Accounting stays integral (exact by construction), or rounds exactly
//! once, explicitly — so the total is a pure function of the inputs, not
//! of how many calls it took to get there.

pub struct Accounting {
    bytes_exact: u64,
}

impl Accounting {
    pub fn charge(&mut self, elems: usize, num: u64, den: u64) -> u64 {
        // integer accounting: no truncation to drift with call count
        self.bytes_exact += (elems as u64 * num + den / 2) / den.max(1);
        self.bytes_exact
    }

    pub fn budget_micros(window_secs: f64) -> u64 {
        (window_secs * 1_000_000.0).round() as u64
    }
}
