//! detlint fixture — `snapshot-publish-outside-cut`, known-bad.
//!
//! λ snapshots published straight from the training loop, outside the
//! coordinator's rank-replicated cut chokepoint. Mid-step the deferred
//! λ-reduce is unresolved and ranks sit at different schedule points, so
//! the minted generation carries a λ no batch run ever ends with — a
//! generation-pinned query can no longer replay bitwise (invariant 10).

pub struct SnapshotHub;

impl SnapshotHub {
    pub fn generation(&self) -> u64 {
        0
    }
}

pub struct LoopState {
    pub lambda: Vec<f32>,
    pub step: u64,
}

/// Publishing from inside the step body, before the λ-stream drained.
pub fn step_body(hub: &SnapshotHub, state: &LoopState) {
    hub.publish_cut(state.lambda.clone(), state.step); //~ snapshot-publish-outside-cut
}

/// A wrapper does not launder the publication: the call is still a
/// second publication site competing with the coordinator's chokepoint.
pub fn flush_lambda(hub: &SnapshotHub, lambda: Vec<f32>, step: u64) -> u64 {
    let before = hub.generation();
    hub.publish_cut(lambda, step); //~ snapshot-publish-outside-cut
    before + 1
}
