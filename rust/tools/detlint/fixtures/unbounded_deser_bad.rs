//! detlint fixture — `unbounded-deser-alloc`, known-bad.
//!
//! The `read_vec` bug class: a length header lifted straight out of the
//! payload sizes an allocation before anyone checks it against the bytes
//! actually remaining — an 11-byte crafted file driving an 8 GiB reserve.

fn read_u64(r: &mut &[u8]) -> Option<u64> {
    if r.len() < 8 {
        return None;
    }
    let (head, rest) = r.split_at(8);
    *r = rest;
    Some(u64::from_le_bytes(head.try_into().ok()?))
}

/// Allocation sized directly by the wire length — no remaining-payload
/// bound anywhere.
pub fn read_blob(r: &mut &[u8]) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(read_u64(r)? as usize); //~ unbounded-deser-alloc
    out.extend_from_slice(r);
    Some(out)
}

/// Length laundered through a local before reaching `vec!` — still
/// unbounded.
pub fn read_words(r: &mut &[u8]) -> Option<Vec<u64>> {
    let n = read_u64(r)? as usize;
    let mut vals = vec![0u64; n]; //~ unbounded-deser-alloc
    for v in vals.iter_mut() {
        *v = read_u64(r)?;
    }
    Some(vals)
}
