//! detlint fixture — `route-outside-scheduler`, fixed.
//!
//! Routing lives in the scheduler; everyone else asks it. The partition
//! function itself carries an allow naming the contract (in the real
//! tree it lives in `topology.rs`, where the rule is off by scoping).

pub struct Tag(u64);

impl Tag {
    pub fn idx(&self) -> u64 {
        self.0
    }
}

pub struct RingScheduler {
    rings: u64,
}

impl RingScheduler {
    pub fn ring_for(&self, tag: &Tag) -> u64 {
        // detlint: allow(route-outside-scheduler) — this *is* the scheduler's
        // partition function; fixtures sit outside topology.rs, so say so
        tag.idx() % self.rings.max(1)
    }
}

/// Everyone else routes by asking the scheduler.
pub fn dispatch(sched: &RingScheduler, tag: &Tag) -> u64 {
    sched.ring_for(tag)
}
