//! detlint fixture — `route-outside-scheduler`, known-bad.
//!
//! Ring routing re-derived outside `RingScheduler`: the two copies agree
//! today, and the first time one changes (weighting, occupancy, a new
//! ring class) ranks route the same tag to different rings.

pub struct Tag(u64);

impl Tag {
    pub fn idx(&self) -> u64 {
        self.0
    }
}

/// A hand-rolled copy of the scheduler's partition function.
pub fn ring_for(tag: &Tag, rings: u64) -> u64 {
    tag.idx() % rings.max(1) //~ route-outside-scheduler
}

/// Same arithmetic hidden behind different names.
pub fn spread(seq: u64, ring_count: u64) -> u64 {
    seq % ring_count //~ route-outside-scheduler
}
