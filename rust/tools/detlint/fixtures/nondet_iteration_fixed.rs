//! detlint fixture — `nondet-iteration`, fixed.
//!
//! Ordered containers make iteration order part of the type: every rank
//! walks the same sequence. A lookup-only hash cache survives behind an
//! allow that says *why* iteration order cannot leak.

use std::collections::{BTreeMap, BTreeSet};

/// Same blob on every rank: `BTreeMap` iterates in key order.
pub fn weight_blob(weights: &BTreeMap<u64, f32>) -> Vec<f32> {
    weights.values().copied().collect()
}

pub fn seen_routes(ids: &[u64]) -> usize {
    let seen: BTreeSet<u64> = ids.iter().copied().collect();
    seen.len()
}

pub struct ExeCache {
    // detlint: allow(nondet-iteration) — lookup-only by key; never iterated,
    // so hash order cannot reach a reduce, a route, or a blob
    inner: std::collections::HashMap<String, u64>,
}

impl ExeCache {
    pub fn get(&self, name: &str) -> Option<u64> {
        self.inner.get(name).copied()
    }
}
