//! detlint fixture — `snapshot-publish-outside-cut`, fixed.
//!
//! Publication is structural: the step body marks a publication as *due*
//! (a pure function of the step index, so every rank agrees on where the
//! cut falls) and the one chokepoint — which resolves the deferred
//! λ-reduce first — performs it. The chokepoint's own `publish_cut` call
//! carries the allow, exactly like `publish_lambda_cut` in the real
//! coordinator; everything else routes through it.

pub struct SnapshotHub;

pub struct LoopState {
    pub lambda: Vec<f32>,
    pub step: u64,
}

/// Cut cadence as a pure function of the step index: rank-replicated.
pub fn publish_due(step: u64, every: u64, steps: u64) -> bool {
    step % every.max(1) == 0 || step == steps
}

/// The one publication site, entered only at rank-replicated cuts with
/// the λ-stream already drained.
pub fn publish_lambda_cut(hub: &SnapshotHub, state: &LoopState) {
    // detlint: allow(snapshot-publish-outside-cut) — this IS the
    // rank-replicated cut chokepoint (invariant 10); the fixture mirrors
    // the real coordinator's one allowed publication site
    hub.publish_cut(state.lambda.clone(), state.step);
}

/// The step body only decides *whether* a cut is due, never publishes.
pub fn step_body(
    hub: &SnapshotHub,
    state: &LoopState,
    every: u64,
    steps: u64,
) {
    if publish_due(state.step, every, steps) {
        publish_lambda_cut(hub, state);
    }
}
