//! detlint fixture — `nondet-iteration`, known-bad.
//!
//! Hash iteration order is seeded per process: two ranks walking "the
//! same" map serialize different blobs, route different reduces, retune
//! to different bucket sizes. (Not compiled; scanned by the fixture tests.)

use std::collections::HashMap; //~ nondet-iteration
use std::collections::HashSet; //~ nondet-iteration

/// Checkpoint blob built by map iteration: rank-divergent byte order.
pub fn weight_blob(weights: &HashMap<u64, f32>) -> Vec<f32> { //~ nondet-iteration
    weights.values().copied().collect()
}

/// Route dedup through a hash set: `len()` is fine, but the first
/// iteration someone adds diverges across ranks.
pub fn seen_routes(ids: &[u64]) -> usize {
    let seen: HashSet<u64> = ids.iter().copied().collect(); //~ nondet-iteration
    seen.len()
}
