//! detlint fixture — `compress-ctrl-tag`, fixed.
//!
//! Codec choice is structural: the policy's `codec_for` chokepoint (in
//! the real tree it lives in `collective/compress.rs`, where the rule is
//! off by scoping) hardwires Ctrl and λ to `None`, and call sites apply
//! whatever it returns without naming the tag next to the codec. The
//! chokepoint shape itself stays clean even here because the tag match
//! and the compression call sit in different statements.

pub enum ReduceTag {
    Theta,
    Lambda,
    Ctrl,
}

#[derive(Clone, Copy)]
pub enum Codec {
    None,
    F16,
}

pub struct CompressPolicy {
    theta: Codec,
}

impl CompressPolicy {
    /// The one place a codec meets a tag.
    pub fn codec_for(&self, tag: &ReduceTag) -> Codec {
        match tag {
            ReduceTag::Theta => self.theta,
            ReduceTag::Lambda | ReduceTag::Ctrl => Codec::None,
        }
    }
}

pub fn quantize_ef(_codec: Codec, _data: &mut [f32], _res: &mut [f32]) {}

/// Callers apply the policy's verdict without re-deciding per tag.
pub fn submit(
    policy: &CompressPolicy,
    tag: &ReduceTag,
    data: &mut [f32],
    res: &mut [f32],
) {
    let codec = policy.codec_for(tag);
    quantize_ef(codec, data, res);
}
