//! detlint fixture — `lock-across-recv`, fixed.
//!
//! Guards end before any rendezvous: copy out what the rendezvous needs,
//! release the lock (block scope or explicit `drop`), then meet the
//! peer. No rank can wedge the ring by sitting on shared state.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

pub fn recv_then_lock(state: &Mutex<Vec<u64>>, rx: &Receiver<u64>) -> u64 {
    let word = rx.recv().expect("ring peer hung up");
    {
        let mut pending = state.lock().expect("collective state lock poisoned");
        pending.push(word);
    }
    word
}

pub fn publish_after_drop(state: &Mutex<Vec<u64>>, tx: &Sender<u64>) {
    let guard = state.lock().expect("collective state lock poisoned");
    let snapshot: Vec<u64> = guard.clone();
    drop(guard);
    for w in snapshot {
        tx.send(w).expect("ring peer hung up");
    }
}
