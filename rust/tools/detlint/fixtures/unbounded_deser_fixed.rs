//! detlint fixture — `unbounded-deser-alloc`, fixed.
//!
//! Same decoder, with every wire length checked against the bytes
//! actually remaining before it sizes anything — the
//! `checkpoint::read_len_bounded` pattern.

fn read_u64(r: &mut &[u8]) -> Option<u64> {
    if r.len() < 8 {
        return None;
    }
    let (head, rest) = r.split_at(8);
    *r = rest;
    Some(u64::from_le_bytes(head.try_into().ok()?))
}

/// Read a length header and require `len * elem_bytes` to fit in the
/// remaining payload before anyone allocates from it.
fn read_len_bounded(r: &mut &[u8], elem_bytes: usize) -> Option<usize> {
    let raw = read_u64(r)?;
    let len = usize::try_from(raw).ok()?;
    let need = len.checked_mul(elem_bytes.max(1))?;
    if need <= r.len() {
        Some(len)
    } else {
        None
    }
}

pub fn read_blob(r: &mut &[u8]) -> Option<Vec<u8>> {
    let len = read_len_bounded(r, 1)?;
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(&r[..len]);
    *r = &r[len..];
    Some(out)
}

pub fn read_words(r: &mut &[u8]) -> Option<Vec<u64>> {
    let n = read_len_bounded(r, 8)?;
    let mut vals = vec![0u64; n];
    for v in vals.iter_mut() {
        *v = read_u64(r)?;
    }
    Some(vals)
}

/// Clamping to the remaining payload also counts as a bound.
pub fn read_tail(r: &mut &[u8]) -> Option<Vec<u8>> {
    let len = read_u64(r)? as usize;
    let len = len.min(r.len());
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(&r[..len]);
    Some(out)
}
