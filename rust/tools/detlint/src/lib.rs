//! detlint — in-tree determinism & concurrency static analysis.
//!
//! The repo's whole comm stack rests on one invariant: every
//! routing/retune decision consumes only rank-replicated inputs, so
//! results are bitwise-identical across topology × policy × ring count
//! (see `docs/INVARIANTS.md`). Example-based tests catch a broken
//! invariant *after* someone writes the test; this pass rejects the known
//! bug classes at CI time, in any new code path, before a test exists:
//!
//! | rule | bug class |
//! |------|-----------|
//! | `nondet-iteration` | hash-order iteration reaching a reduce/route/blob |
//! | `wallclock-in-decision` | wall clock feeding a rank-replicated decision |
//! | `unbounded-deser-alloc` | length header sizing an allocation unbounded |
//! | `lock-across-recv` | mutex guard held across a ring rendezvous |
//! | `float-accum-cast` | unrounded int cast of a float accumulator |
//! | `route-outside-scheduler` | ring arithmetic outside `RingScheduler` |
//! | `shard-outside-partition` | world-partition arithmetic outside `owned_ranges` |
//! | `compress-ctrl-tag` | lossy codec reaching a Ctrl-tagged reduce |
//! | `snapshot-publish-outside-cut` | λ snapshot minted off the coordinator cut |
//! | `bad-allow` | broken `detlint:` directive |
//!
//! Intentional exceptions are annotated in place:
//!
//! ```text
//! // detlint: allow(<rule>[, <rule>…]) — <reason>
//! ```
//!
//! on the offending line or the line above it. The reason is mandatory —
//! an allow is documentation of *why* the invariant holds anyway, not an
//! opt-out. Known-bad/known-good examples for every rule live under
//! `fixtures/` and are pinned by this crate's tests; the `sama` crate's
//! tier-1 `detlint_clean` test pins the real tree at zero findings.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};

pub use rules::{
    Finding, BAD_ALLOW, COMPRESS_CTRL_TAG, FLOAT_ACCUM_CAST, LOCK_ACROSS_RECV,
    NONDET_ITERATION, ROUTE_OUTSIDE_SCHEDULER, RULES, SHARD_OUTSIDE_PARTITION,
    SNAPSHOT_PUBLISH_OUTSIDE_CUT, UNBOUNDED_DESER_ALLOC, WALLCLOCK_IN_DECISION,
};

/// Lint one source string. `path_label` determines rule scoping (see
/// `rules::FileClass`) and is echoed in findings.
pub fn scan_source(path_label: &str, src: &str) -> Vec<Finding> {
    rules::scan_source(path_label, src)
}

/// Lint one file on disk.
pub fn scan_path(path: &Path) -> std::io::Result<Vec<Finding>> {
    let src = std::fs::read_to_string(path)?;
    Ok(scan_source(&path.to_string_lossy(), &src))
}

/// Lint every `.rs` file under `roots` (recursively, in sorted order so
/// output is deterministic — this tool lints for determinism; it had
/// better report deterministically). Returns the findings plus how many
/// files were scanned, so callers can assert the walk actually saw the
/// tree.
pub fn scan_tree(roots: &[PathBuf]) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut files = Vec::new();
    for root in roots {
        collect_rs_files(root, &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        findings.extend(scan_path(f)?);
    }
    Ok((findings, files.len()))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if dir.is_file() {
        if dir.extension().is_some_and(|e| e == "rs") {
            out.push(dir.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Render findings in the canonical `file:line · rule · snippet` format.
pub fn render(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| format!("{}:{} · {} · {}", f.file, f.line, f.rule, f.snippet))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod fixture_tests {
    use super::*;

    fn fixture_path(name: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
    }

    /// `//~ <rule>` markers in a fixture are its expected diagnostics.
    fn expected(src: &str) -> Vec<(usize, String)> {
        src.lines()
            .enumerate()
            .filter_map(|(i, l)| {
                let marker = l.split("//~").nth(1)?;
                let rule = marker.split_whitespace().next()?;
                Some((i + 1, rule.to_string()))
            })
            .collect()
    }

    /// A known-bad fixture must produce *exactly* its marked diagnostics —
    /// same lines, same rules, nothing extra, nothing missed.
    fn assert_fixture_exact(name: &str) {
        let path = fixture_path(name);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
        let want = expected(&src);
        assert!(!want.is_empty(), "{name}: fixture has no //~ markers");
        let got: Vec<(usize, String)> = scan_source(&path.to_string_lossy(), &src)
            .into_iter()
            .map(|f| (f.line, f.rule.to_string()))
            .collect();
        assert_eq!(
            got, want,
            "{name}: findings (left) != //~ markers (right)"
        );
    }

    /// A fixed fixture must be completely clean.
    fn assert_fixture_clean(name: &str) {
        let path = fixture_path(name);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
        let findings = scan_source(&path.to_string_lossy(), &src);
        assert!(
            findings.is_empty(),
            "{name} should be clean:\n{}",
            render(&findings)
        );
    }

    #[test]
    fn nondet_iteration_bad() {
        assert_fixture_exact("nondet_iteration_bad.rs");
    }

    #[test]
    fn nondet_iteration_fixed() {
        assert_fixture_clean("nondet_iteration_fixed.rs");
    }

    #[test]
    fn wallclock_bad() {
        assert_fixture_exact("wallclock_bad.rs");
    }

    #[test]
    fn wallclock_fixed() {
        assert_fixture_clean("wallclock_fixed.rs");
    }

    #[test]
    fn unbounded_deser_bad() {
        assert_fixture_exact("unbounded_deser_bad.rs");
    }

    #[test]
    fn unbounded_deser_fixed() {
        assert_fixture_clean("unbounded_deser_fixed.rs");
    }

    #[test]
    fn lock_across_recv_bad() {
        assert_fixture_exact("lock_across_recv_bad.rs");
    }

    #[test]
    fn lock_across_recv_fixed() {
        assert_fixture_clean("lock_across_recv_fixed.rs");
    }

    #[test]
    fn float_accum_cast_bad() {
        assert_fixture_exact("float_accum_cast_bad.rs");
    }

    #[test]
    fn float_accum_cast_fixed() {
        assert_fixture_clean("float_accum_cast_fixed.rs");
    }

    #[test]
    fn route_outside_scheduler_bad() {
        assert_fixture_exact("route_outside_scheduler_bad.rs");
    }

    #[test]
    fn route_outside_scheduler_fixed() {
        assert_fixture_clean("route_outside_scheduler_fixed.rs");
    }

    #[test]
    fn shard_outside_partition_bad() {
        assert_fixture_exact("shard_outside_partition_bad.rs");
    }

    #[test]
    fn shard_outside_partition_fixed() {
        assert_fixture_clean("shard_outside_partition_fixed.rs");
    }

    #[test]
    fn compress_ctrl_tag_bad() {
        assert_fixture_exact("compress_ctrl_tag_bad.rs");
    }

    #[test]
    fn compress_ctrl_tag_fixed() {
        assert_fixture_clean("compress_ctrl_tag_fixed.rs");
    }

    #[test]
    fn snapshot_publish_outside_cut_bad() {
        assert_fixture_exact("snapshot_publish_outside_cut_bad.rs");
    }

    #[test]
    fn snapshot_publish_outside_cut_fixed() {
        assert_fixture_clean("snapshot_publish_outside_cut_fixed.rs");
    }

    #[test]
    fn allow_bad() {
        assert_fixture_exact("allow_bad.rs");
    }

    #[test]
    fn allow_fixed() {
        assert_fixture_clean("allow_fixed.rs");
    }

    /// The whole fixture set through `scan_tree`: one diagnostic per seeded
    /// violation, nonzero total — the CI-lane acceptance shape.
    #[test]
    fn fixture_tree_totals() {
        let (findings, files) =
            scan_tree(&[fixture_path("")]).expect("scan fixtures");
        assert_eq!(files, 20, "fixture files present");
        let total_markers: usize = std::fs::read_dir(fixture_path(""))
            .unwrap()
            .map(|e| {
                let p = e.unwrap().path();
                let src = std::fs::read_to_string(&p).unwrap();
                expected(&src).len()
            })
            .sum();
        assert_eq!(findings.len(), total_markers);
        assert!(findings.len() >= 18, "≥ 9 rules exercised, twice over");
    }

    /// Allow directives must not leak across lines: an allow for line N
    /// does not cover line N+2.
    #[test]
    fn allow_is_line_scoped() {
        let src = "\
// detlint: allow(nondet-iteration) — covers only the next line
use std::collections::HashMap;
use std::collections::HashSet;
";
        let findings = scan_source("fixtures/inline.rs", src);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].line, 3);
        assert_eq!(findings[0].rule, NONDET_ITERATION);
    }

    /// An allow naming rule A does not suppress rule B on the same line.
    #[test]
    fn allow_is_rule_scoped() {
        let src = "\
use std::collections::HashMap; // detlint: allow(wallclock-in-decision) — wrong rule named
";
        let findings = scan_source("fixtures/inline.rs", src);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, NONDET_ITERATION);
    }
}
