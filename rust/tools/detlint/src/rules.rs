//! The detlint rules: token-shape patterns over [`crate::lexer`] output,
//! each enforcing one invariant from `docs/INVARIANTS.md`.
//!
//! Rules are deliberately *heuristic* — this is a lint, not a type system.
//! Each one is tuned to catch the bug class it is named for (every one has
//! shipped, or nearly shipped, in this repo — see the PR history in
//! CHANGES.md) with zero false positives on the current tree; anything
//! intentional carries a `// detlint: allow(<rule>) — <reason>` comment, so
//! the exceptions are enumerable and justified at the point of use.
//!
//! Scoping: some rules apply everywhere, some only to the *decision
//! modules* — the rank-replicated code (`collective`, `coordinator`,
//! `config`, `algos`, `bilevel`) whose outputs must be bitwise-identical
//! across ranks — and one only to `collective` (the only module that holds
//! locks near channel rendezvous). Fixture files under `fixtures/` are
//! classed as strict (decision + collective) so every rule is exercisable.

use std::collections::BTreeSet;

use crate::lexer::{self, Lexed, TokKind, Token};

/// `HashMap`/`HashSet` anywhere iteration order could reach a reduce, a
/// route, or a checkpoint blob. Hash iteration order is seeded per process:
/// two ranks walking "the same" map diverge bitwise. Use `BTreeMap`/`Vec`.
pub const NONDET_ITERATION: &str = "nondet-iteration";
/// `Instant::now()` / `SystemTime` in a decision module. Wall clock is the
/// canonical rank-divergent input; it may only feed routing/retuning through
/// the Ctrl-synced profile path (which averages it across ranks first).
pub const WALLCLOCK_IN_DECISION: &str = "wallclock-in-decision";
/// A freshly read length (`read_u64(..)? as usize` and friends) sizing an
/// allocation with no remaining-payload bound — the `read_vec` bug class:
/// a tiny crafted file driving an 8 GiB `Vec::with_capacity`.
pub const UNBOUNDED_DESER_ALLOC: &str = "unbounded-deser-alloc";
/// A `Mutex` guard held across a channel `recv()`/`send()` rendezvous in
/// `collective` — the classic ring deadlock (peer blocked on the lock can
/// never arrive at the rendezvous).
pub const LOCK_ACROSS_RECV: &str = "lock-across-recv";
/// Integer `as` cast on a float accumulator without an explicit rounding —
/// the PR 1 bytes-accounting bug class: per-call truncation drifting with
/// call count.
pub const FLOAT_ACCUM_CAST: &str = "float-accum-cast";
/// Ring-routing arithmetic (`tag.idx() % …`, `% rings`) outside
/// `RingScheduler` — routing decided in two places is routing that can
/// disagree across ranks the first time one copy changes.
pub const ROUTE_OUTSIDE_SCHEDULER: &str = "route-outside-scheduler";
/// World-partition arithmetic (`% world`, `/ world` and friends) outside
/// `collective::owned_ranges`/`chunk_range` — the invariant-8 chokepoint.
/// Shard ownership derived in two places is ownership that can disagree
/// across ranks (or with the checkpoint reassembly) the first time one
/// copy changes: a rank would update m/v slices another rank also claims,
/// and the all-gather would re-replicate divergent θ.
pub const SHARD_OUTSIDE_PARTITION: &str = "shard-outside-partition";
/// A lossy codec reaching a `Ctrl`-tagged reduce. Ctrl payloads carry the
/// rank-averaged profile sums every rank must agree on bitwise before it
/// retunes routing — quantizing them desynchronizes those decisions. The
/// codec choice lives behind the one `codec_for` chokepoint in
/// `collective/compress.rs` (which hardwires Ctrl and λ to `None`); a
/// statement naming `Ctrl` next to a compression call anywhere else is
/// re-deciding it.
pub const COMPRESS_CTRL_TAG: &str = "compress-ctrl-tag";
/// A λ snapshot publication (`publish_cut(…)`) anywhere but the
/// coordinator's rank-replicated cut chokepoint. The serving hub's
/// generation counter is the query-pinning contract: a snapshot minted
/// mid-step (deferred λ-reduce unresolved, ranks at different schedule
/// points) hands readers a λ no batch run ever ends with, silently
/// breaking the bitwise replay guarantee of invariant 10. The hub method
/// itself lives in `serve/snapshot.rs` (exempt); the coordinator's
/// chokepoint carries the one allow.
pub const SNAPSHOT_PUBLISH_OUTSIDE_CUT: &str = "snapshot-publish-outside-cut";
/// A malformed `detlint:` directive: unknown rule name, missing `— reason`,
/// or unparseable `allow(…)`. Allows are load-bearing documentation; a
/// broken one silently enforces nothing.
pub const BAD_ALLOW: &str = "bad-allow";

/// Every rule name, for directive validation and `--help`.
pub const RULES: [&str; 10] = [
    NONDET_ITERATION,
    WALLCLOCK_IN_DECISION,
    UNBOUNDED_DESER_ALLOC,
    LOCK_ACROSS_RECV,
    FLOAT_ACCUM_CAST,
    ROUTE_OUTSIDE_SCHEDULER,
    SHARD_OUTSIDE_PARTITION,
    COMPRESS_CTRL_TAG,
    SNAPSHOT_PUBLISH_OUTSIDE_CUT,
    BAD_ALLOW,
];

/// One diagnostic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    pub rule: &'static str,
    /// The offending source line, trimmed.
    pub snippet: String,
}

/// Which rule scopes a file falls under (derived from its path).
#[derive(Clone, Copy, Debug)]
struct FileClass {
    /// Rank-replicated decision modules (plus fixtures): wallclock and
    /// float-cast rules apply.
    decision: bool,
    /// The collective itself (plus fixtures): lock-across-recv applies.
    collective: bool,
    /// `topology.rs` — the one place routing arithmetic is *supposed* to
    /// live; route-outside-scheduler is skipped there.
    scheduler_home: bool,
    /// `src/collective` — where `owned_ranges`/`chunk_range` (and the ring
    /// hop math) legitimately partition by world; shard-outside-partition
    /// is skipped there. Fixtures stay in scope so the rule is exercisable.
    partition_home: bool,
    /// `compress.rs` — the codec chokepoint, the one place allowed to name
    /// `Ctrl` while deciding a codec (its tests pin the Ctrl→`None`
    /// mapping); compress-ctrl-tag is skipped there. Fixture file names
    /// carry a `compress_ctrl_tag_` prefix, so fixtures stay in scope.
    compress_home: bool,
    /// `serve/snapshot.rs` — where `SnapshotHub::publish_cut` is defined
    /// (and unit-tested); snapshot-publish-outside-cut is skipped there.
    /// Fixture file names carry a `snapshot_publish_outside_cut_` prefix,
    /// so fixtures stay in scope.
    snapshot_home: bool,
}

impl FileClass {
    fn classify(path: &str) -> FileClass {
        let p = path.replace('\\', "/");
        let fixture = p.contains("fixtures/");
        let decision = fixture
            || [
                "src/collective",
                "src/coordinator",
                "src/config",
                "src/algos",
                "src/bilevel",
            ]
            .iter()
            .any(|m| p.contains(m));
        FileClass {
            decision,
            collective: fixture || p.contains("src/collective"),
            scheduler_home: p.ends_with("topology.rs"),
            partition_home: p.contains("src/collective"),
            compress_home: p.ends_with("compress.rs"),
            snapshot_home: p.ends_with("serve/snapshot.rs"),
        }
    }
}

/// Lint one file's source. `path_label` is used for scoping and reporting.
pub fn scan_source(path_label: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let class = FileClass::classify(path_label);
    let lines: Vec<&str> = src.lines().collect();
    let mut raw: Vec<(usize, &'static str)> = Vec::new();

    rule_nondet_iteration(&lexed.tokens, &mut raw);
    rule_unbounded_deser_alloc(&lexed.tokens, &mut raw);
    if class.decision {
        rule_wallclock(&lexed.tokens, &mut raw);
        rule_float_accum_cast(&lexed.tokens, &mut raw);
    }
    if class.collective {
        rule_lock_across_recv(&lexed.tokens, &mut raw);
    }
    if !class.scheduler_home {
        rule_route_outside_scheduler(&lexed.tokens, &mut raw);
    }
    if class.decision && !class.partition_home {
        rule_shard_outside_partition(&lexed.tokens, &mut raw);
    }
    if !class.compress_home {
        rule_compress_ctrl_tag(&lexed.tokens, &mut raw);
    }
    if !class.snapshot_home {
        rule_snapshot_publish(&lexed.tokens, &mut raw);
    }

    // detlint: directives — build the suppression map, flag broken ones
    let mut allowed: BTreeSet<(usize, &'static str)> = BTreeSet::new();
    for d in &lexed.allows {
        if d.malformed {
            raw.push((d.line, BAD_ALLOW));
            continue;
        }
        let mut ok = d.has_reason;
        if !d.has_reason {
            raw.push((d.line, BAD_ALLOW));
        }
        let mut canon: Vec<&'static str> = Vec::new();
        for r in &d.rules {
            match RULES.iter().find(|known| *known == r) {
                Some(known) => canon.push(known),
                None => {
                    raw.push((d.line, BAD_ALLOW));
                    ok = false;
                }
            }
        }
        if !ok {
            continue; // a broken allow suppresses nothing
        }
        let target = if d.inline {
            d.line
        } else {
            // applies to the next code line after the comment
            match lexed.tokens.iter().find(|t| t.line > d.line) {
                Some(t) => t.line,
                None => continue,
            }
        };
        for rule in canon {
            allowed.insert((target, rule));
        }
    }

    raw.sort();
    raw.dedup();
    raw.into_iter()
        .filter(|(line, rule)| {
            *rule == BAD_ALLOW || !allowed.contains(&(*line, *rule))
        })
        .map(|(line, rule)| Finding {
            file: path_label.to_string(),
            line,
            rule,
            snippet: snippet(&lines, line),
        })
        .collect()
}

fn snippet(lines: &[&str], line: usize) -> String {
    let s = lines.get(line - 1).map(|l| l.trim()).unwrap_or("");
    if s.chars().count() > 96 {
        let cut: String = s.chars().take(93).collect();
        format!("{cut}…")
    } else {
        s.to_string()
    }
}

// ---------------------------------------------------------------------------
// individual rules
// ---------------------------------------------------------------------------

fn rule_nondet_iteration(toks: &[Token], out: &mut Vec<(usize, &'static str)>) {
    for t in toks {
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            out.push((t.line, NONDET_ITERATION));
        }
    }
}

fn rule_wallclock(toks: &[Token], out: &mut Vec<(usize, &'static str)>) {
    for (i, t) in toks.iter().enumerate() {
        if t.is_ident("SystemTime") {
            out.push((t.line, WALLCLOCK_IN_DECISION));
        }
        if t.is_ident("Instant")
            && toks.get(i + 1).is_some_and(|t| t.is_op("::"))
            && toks.get(i + 2).is_some_and(|t| t.is_ident("now"))
        {
            out.push((t.line, WALLCLOCK_IN_DECISION));
        }
    }
}

fn rule_route_outside_scheduler(
    toks: &[Token],
    out: &mut Vec<(usize, &'static str)>,
) {
    for (i, t) in toks.iter().enumerate() {
        // `<anything>.idx() % …` — the tag-partition arithmetic
        if t.is_ident("idx")
            && toks.get(i + 1).is_some_and(|t| t.is_op("("))
            && toks.get(i + 2).is_some_and(|t| t.is_op(")"))
            && toks.get(i + 3).is_some_and(|t| t.is_op("%"))
        {
            out.push((t.line, ROUTE_OUTSIDE_SCHEDULER));
        }
        // `% <ring-named operand>` — modulo by a ring count
        if t.is_op("%") {
            let mut j = i + 1;
            while toks.get(j).is_some_and(|t| {
                t.is_op("(") || t.is_op("&") || t.is_op("*") || t.is_op(".")
                    || t.is_ident("self")
            }) {
                j += 1;
            }
            if let Some(rhs) = toks.get(j) {
                if rhs.kind == TokKind::Ident
                    && rhs.text.to_ascii_lowercase().contains("ring")
                {
                    out.push((t.line, ROUTE_OUTSIDE_SCHEDULER));
                }
            }
        }
    }
}

fn rule_shard_outside_partition(
    toks: &[Token],
    out: &mut Vec<(usize, &'static str)>,
) {
    for (i, t) in toks.iter().enumerate() {
        if !(t.is_op("%") || t.is_op("/")) {
            continue;
        }
        // walk the short postfix chain on the right-hand side
        // (`world`, `self.world`, `coll.world()`, `(world - 1)`…): a
        // world-named ident makes this partition arithmetic
        let mut j = i + 1;
        let mut hops = 0usize;
        while let Some(rhs) = toks.get(j) {
            let continues = match rhs.kind {
                TokKind::Ident => true,
                TokKind::Op => {
                    matches!(rhs.text.as_str(), "(" | "&" | "*" | "." | "::")
                }
                _ => false,
            };
            if !continues || hops >= 8 {
                break;
            }
            if rhs.kind == TokKind::Ident
                && rhs.text.to_ascii_lowercase().contains("world")
            {
                out.push((t.line, SHARD_OUTSIDE_PARTITION));
                break;
            }
            hops += 1;
            j += 1;
        }
    }
}

/// Compression-application calls: a statement naming one of these *and*
/// the `Ctrl` tag is choosing a codec for the control stream. Type names
/// (`CompressPolicy`, `Codec`) and plain `codec` bindings are deliberately
/// not in this set — constructing a θ policy in the same statement that
/// mentions `Ctrl` (a test sweeping tags, say) is not an application.
const COMPRESS_APPLY: [&str; 5] =
    ["on_submit", "quantize", "quantize_ef", "dequantize", "codec_for"];

fn rule_compress_ctrl_tag(
    toks: &[Token],
    out: &mut Vec<(usize, &'static str)>,
) {
    for span in statements(toks) {
        if !span.iter().any(|t| t.is_ident("Ctrl")) {
            continue;
        }
        // one finding per statement, anchored at the application call
        if let Some(apply) = span
            .iter()
            .find(|t| COMPRESS_APPLY.iter().any(|a| t.is_ident(a)))
        {
            out.push((apply.line, COMPRESS_CTRL_TAG));
        }
    }
}

fn rule_snapshot_publish(toks: &[Token], out: &mut Vec<(usize, &'static str)>) {
    for (i, t) in toks.iter().enumerate() {
        // any `publish_cut(…)` call (or `fn publish_cut(` re-definition) —
        // λ publication concentrated at one chokepoint is the invariant
        if t.is_ident("publish_cut")
            && toks.get(i + 1).is_some_and(|t| t.is_op("("))
        {
            out.push((t.line, SNAPSHOT_PUBLISH_OUTSIDE_CUT));
        }
    }
}

const INT_TARGETS: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64",
    "i128", "isize",
];
const ROUNDING: [&str; 6] =
    ["round", "floor", "ceil", "trunc", "round_ties_even", "to_bits"];

/// Walk backwards from the token before `as`, collecting the cast's operand
/// (the postfix expression chain `as` binds to).
fn cast_operand<'a>(toks: &'a [Token], as_idx: usize) -> Vec<&'a Token> {
    let mut operand = Vec::new();
    let mut depth = 0usize;
    let mut j = as_idx;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        if t.is_op(")") || t.is_op("]") {
            depth += 1;
            operand.push(t);
            continue;
        }
        if t.is_op("(") || t.is_op("[") {
            if depth == 0 {
                break;
            }
            depth -= 1;
            operand.push(t);
            continue;
        }
        if depth > 0 {
            operand.push(t);
            continue;
        }
        match t.kind {
            TokKind::Ident | TokKind::Int | TokKind::Float | TokKind::Str
            | TokKind::Char => operand.push(t),
            TokKind::Op if t.text == "." || t.text == "::" || t.text == "?" => {
                operand.push(t)
            }
            _ => break,
        }
    }
    operand
}

fn rule_float_accum_cast(toks: &[Token], out: &mut Vec<(usize, &'static str)>) {
    // First pass: names bound/accumulated from float-shaped expressions.
    // `let exact = … as f64 …;` or `self.bytes_exact += … * 2.0;` make
    // `exact` / `bytes_exact` float accumulators for the second pass.
    let mut float_vars: BTreeSet<&str> = BTreeSet::new();
    for span in statements(toks) {
        if !span_has_float_indicator(span, &float_vars) {
            continue;
        }
        // `let [mut] name = …`
        if let Some(k) = span.iter().position(|t| t.is_ident("let")) {
            let mut m = k + 1;
            if span.get(m).is_some_and(|t| t.is_ident("mut")) {
                m += 1;
            }
            if let Some(name) = span.get(m) {
                if name.kind == TokKind::Ident {
                    float_vars.insert(&name.text);
                }
            }
        }
        // `name += …` / `name = …` (possibly `self.name`, possibly
        // `name[idx] = …` — an indexed store accumulates into `name`,
        // not `idx`, so skip back over the index expression first)
        for (k, t) in span.iter().enumerate() {
            if t.is_op("+=") || t.is_op("=") {
                let mut m = k;
                while m > 0 && span[m - 1].is_op("]") {
                    let mut depth = 1usize;
                    m -= 1;
                    while m > 0 && depth > 0 {
                        m -= 1;
                        if span[m].is_op("]") {
                            depth += 1;
                        } else if span[m].is_op("[") {
                            depth -= 1;
                        }
                    }
                }
                if let Some(name) = span[..m]
                    .iter()
                    .rev()
                    .find(|t| t.kind == TokKind::Ident)
                {
                    float_vars.insert(&name.text);
                }
                break;
            }
        }
    }
    // Second pass: integer casts whose operand smells like a float.
    for (i, t) in toks.iter().enumerate() {
        if !t.is_ident("as") {
            continue;
        }
        let Some(target) = toks.get(i + 1) else { continue };
        if !INT_TARGETS.iter().any(|ty| target.is_ident(ty)) {
            continue;
        }
        let operand = cast_operand(toks, i);
        let floaty = operand.iter().any(|t| {
            t.kind == TokKind::Float
                || t.is_ident("f32")
                || t.is_ident("f64")
                || t.is_ident("as_secs_f64")
                || t.is_ident("as_secs_f32")
                || t.is_ident("elapsed")
                || (t.kind == TokKind::Ident && float_vars.contains(t.text.as_str()))
        });
        let rounded = operand
            .iter()
            .any(|t| ROUNDING.iter().any(|r| t.is_ident(r)));
        if floaty && !rounded {
            out.push((t.line, FLOAT_ACCUM_CAST));
        }
    }
}

fn span_has_float_indicator(span: &[Token], float_vars: &BTreeSet<&str>) -> bool {
    span.iter().any(|t| {
        t.kind == TokKind::Float
            || t.is_ident("f32")
            || t.is_ident("f64")
            || t.is_ident("as_secs_f64")
            || t.is_ident("as_secs_f32")
            || (t.kind == TokKind::Ident && float_vars.contains(t.text.as_str()))
    })
}

/// Split a token stream into rough statements: boundaries at `;` outside
/// `()`/`[]` groups and at every brace. Good enough to scope taint within a
/// statement without parsing.
fn statements(toks: &[Token]) -> Vec<&[Token]> {
    let mut spans = Vec::new();
    let (mut paren, mut bracket) = (0usize, 0usize);
    let mut start = 0usize;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Op {
            continue;
        }
        match t.text.as_str() {
            "(" => paren += 1,
            ")" => paren = paren.saturating_sub(1),
            "[" => bracket += 1,
            "]" => bracket = bracket.saturating_sub(1),
            ";" if paren == 0 && bracket == 0 => {
                spans.push(&toks[start..=i]);
                start = i + 1;
            }
            "{" | "}" => {
                if start < i {
                    spans.push(&toks[start..i]);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < toks.len() {
        spans.push(&toks[start..]);
    }
    spans
}

/// Idents that mark a length as bounded within a statement.
const BOUND_IDENTS: [&str; 6] = [
    "read_len_bounded",
    "checked_mul",
    "min",
    "clamp",
    "try_from",
    "try_into",
];
/// Allocation sites a tainted length must not reach.
const ALLOC_IDENTS: [&str; 4] = ["with_capacity", "resize", "reserve", "vec"];

fn span_bounded(span: &[Token]) -> bool {
    span.iter().any(|t| {
        BOUND_IDENTS.iter().any(|b| t.is_ident(b))
            || t.is_op("<=")
            || t.is_op(">=")
    })
}

/// The allocation token in a span, if any (`vec` only counts as the `vec!`
/// macro).
fn span_alloc<'a>(span: &'a [Token]) -> Option<&'a Token> {
    span.iter().enumerate().find_map(|(k, t)| {
        let is_alloc = ALLOC_IDENTS.iter().any(|a| t.is_ident(a));
        if !is_alloc {
            return None;
        }
        if t.is_ident("vec")
            && !span.get(k + 1).is_some_and(|t| t.is_op("!"))
        {
            return None;
        }
        Some(t)
    })
}

fn span_reads_len(span: &[Token]) -> bool {
    let reads = span.iter().any(|t| {
        t.kind == TokKind::Ident
            && (t.text.starts_with("read_")
                || t.text == "from_le_bytes"
                || t.text == "from_be_bytes"
                || t.text == "from_ne_bytes")
    });
    let casts = span.iter().enumerate().any(|(k, t)| {
        t.is_ident("as") && span.get(k + 1).is_some_and(|t| t.is_ident("usize"))
    });
    reads && casts
}

fn rule_unbounded_deser_alloc(
    toks: &[Token],
    out: &mut Vec<(usize, &'static str)>,
) {
    let mut tainted: BTreeSet<String> = BTreeSet::new();
    for span in statements(toks) {
        let bounded = span_bounded(span);
        if span_reads_len(span) {
            if bounded {
                continue;
            }
            if let Some(alloc) = span_alloc(span) {
                // direct: `Vec::with_capacity(read_u64(r)? as usize)`
                out.push((alloc.line, UNBOUNDED_DESER_ALLOC));
                continue;
            }
            // `let [mut] name = read_…? as usize;` → taint
            if let Some(k) = span.iter().position(|t| t.is_ident("let")) {
                let mut m = k + 1;
                if span.get(m).is_some_and(|t| t.is_ident("mut")) {
                    m += 1;
                }
                if let Some(name) = span.get(m) {
                    if name.kind == TokKind::Ident {
                        tainted.insert(name.text.clone());
                    }
                }
            }
            continue;
        }
        let uses_tainted = span.iter().any(|t| {
            t.kind == TokKind::Ident && tainted.contains(&t.text)
        });
        if !uses_tainted {
            continue;
        }
        if bounded {
            // the length got bounded (min/checked_mul/comparison): clear it
            for t in span {
                if t.kind == TokKind::Ident {
                    tainted.remove(&t.text);
                }
            }
            continue;
        }
        if let Some(alloc) = span_alloc(span) {
            out.push((alloc.line, UNBOUNDED_DESER_ALLOC));
            for t in span {
                if t.kind == TokKind::Ident {
                    tainted.remove(&t.text);
                }
            }
        }
    }
}

const RENDEZVOUS: [&str; 4] = ["recv", "try_recv", "recv_timeout", "send"];

fn rule_lock_across_recv(toks: &[Token], out: &mut Vec<(usize, &'static str)>) {
    // (guard name, brace depth it was bound at)
    let mut guards: Vec<(String, usize)> = Vec::new();
    let mut depth = 0usize;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_op("{") {
            depth += 1;
            i += 1;
            continue;
        }
        if t.is_op("}") {
            depth = depth.saturating_sub(1);
            guards.retain(|(_, d)| *d <= depth);
            i += 1;
            continue;
        }
        // `drop(guard)` releases it
        if t.is_ident("drop")
            && toks.get(i + 1).is_some_and(|t| t.is_op("("))
        {
            if let Some(name) = toks.get(i + 2) {
                guards.retain(|(g, _)| g != &name.text);
            }
            i += 3;
            continue;
        }
        // `let [mut] name = … .lock() …;` binds a guard at this depth
        if t.is_ident("let") {
            let mut m = i + 1;
            if toks.get(m).is_some_and(|t| t.is_ident("mut")) {
                m += 1;
            }
            let name = toks.get(m).filter(|t| t.kind == TokKind::Ident);
            // scan this statement for a `.lock()` call
            let mut j = m;
            let (mut paren, mut bracket) = (0usize, 0usize);
            let mut locks = false;
            while let Some(tj) = toks.get(j) {
                match (tj.kind, tj.text.as_str()) {
                    (TokKind::Op, "(") => paren += 1,
                    (TokKind::Op, ")") => paren = paren.saturating_sub(1),
                    (TokKind::Op, "[") => bracket += 1,
                    (TokKind::Op, "]") => bracket = bracket.saturating_sub(1),
                    (TokKind::Op, ";") if paren == 0 && bracket == 0 => break,
                    (TokKind::Op, "{") | (TokKind::Op, "}") => break,
                    (TokKind::Ident, "lock") => {
                        if toks.get(j + 1).is_some_and(|t| t.is_op("(")) {
                            locks = true;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            if locks {
                if let Some(name) = name {
                    guards.push((name.text.clone(), depth));
                }
            }
            // fall through token by token (rendezvous inside the same
            // statement, e.g. `let x = rx.recv()`, still gets checked)
            i += 1;
            continue;
        }
        if !guards.is_empty()
            && RENDEZVOUS.iter().any(|r| t.is_ident(r))
            && toks.get(i + 1).is_some_and(|t| t.is_op("("))
        {
            out.push((t.line, LOCK_ACROSS_RECV));
        }
        i += 1;
    }
}
