//! Minimal token-level Rust lexer.
//!
//! Just enough lexing for the rule engine in [`crate::rules`]: identifiers,
//! numeric literals (with a float/int distinction), string/char literals,
//! lifetimes and operators, each carrying its 1-based source line. Comments
//! and literal *contents* are deliberately dropped — every detlint rule is a
//! token-shape pattern, and skipping comments/strings here is precisely what
//! keeps the rules from firing on prose like "`tag.idx() % rings`" in a doc
//! comment.
//!
//! The lexer is also where `// detlint: allow(<rule>) — <reason>` directives
//! are collected (plain `//` comments only; doc comments are prose and never
//! carry directives).

/// Token kind. Keywords are ordinary [`TokKind::Ident`]s — rules match on
/// text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// Integer literal (any base, any suffix except `f32`/`f64`).
    Int,
    /// Float literal (decimal point, exponent started, or `f32`/`f64`
    /// suffix).
    Float,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Character or byte literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Operator / punctuation; multi-character operators (`::`, `=>`,
    /// `..=`) are one token.
    Op,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_op(&self, s: &str) -> bool {
        self.kind == TokKind::Op && self.text == s
    }
}

/// One `// detlint: allow(…)` directive.
#[derive(Clone, Debug)]
pub struct AllowDirective {
    /// Line the directive appears on.
    pub line: usize,
    /// True when the comment shares its line with code — the allow then
    /// applies to that line; otherwise it applies to the next code line.
    pub inline: bool,
    /// Rule names inside `allow(…)`.
    pub rules: Vec<String>,
    /// True when a non-empty justification follows the closing paren.
    pub has_reason: bool,
    /// True when the directive could not be parsed at all (e.g. a
    /// `detlint:` marker without a well-formed `allow(…)`).
    pub malformed: bool,
}

/// Lex output: the token stream plus any allow directives encountered.
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub allows: Vec<AllowDirective>,
}

const OPS3: [&str; 4] = ["..=", "<<=", ">>=", "..."];
const OPS2: [&str; 19] = [
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=", "*=",
    "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

pub fn lex(src: &str) -> Lexed {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens: Vec<Token> = Vec::new();
    let mut allows: Vec<AllowDirective> = Vec::new();

    let at = |i: usize, c: char| i < n && s[i] == c;

    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- comments -------------------------------------------------
        if c == '/' && at(i + 1, '/') {
            let start = i;
            while i < n && s[i] != '\n' {
                i += 1;
            }
            let text: String = s[start..i].iter().collect();
            // doc comments (`///`, `//!`) are prose — no directives there
            let is_doc = text.starts_with("///") || text.starts_with("//!");
            if !is_doc {
                let inline =
                    tokens.last().map(|t| t.line) == Some(line);
                parse_allow(&text, line, inline, &mut allows);
            }
            continue;
        }
        if c == '/' && at(i + 1, '*') {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if s[i] == '/' && at(i + 1, '*') {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && at(i + 1, '/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // ---- raw / byte strings ---------------------------------------
        if let Some((end, newlines)) = raw_string_end(&s, i) {
            tokens.push(Token { kind: TokKind::Str, text: String::new(), line });
            line += newlines;
            i = end;
            continue;
        }
        if c == '"' || (c == 'b' && at(i + 1, '"')) {
            i += usize::from(c == 'b') + 1;
            while i < n {
                if s[i] == '\\' {
                    i += 2;
                } else if s[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if s[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            tokens.push(Token { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        // ---- char literal vs lifetime ---------------------------------
        if c == '\'' || (c == 'b' && at(i + 1, '\'')) {
            let q = i + usize::from(c == 'b'); // index of the quote
            if at(q + 1, '\\') {
                // escaped char literal
                i = q + 2;
                while i < n && s[i] != '\'' {
                    i += 1;
                }
                i += 1;
                tokens.push(Token { kind: TokKind::Char, text: String::new(), line });
                continue;
            }
            if q + 2 < n && s[q + 2] == '\'' && s[q + 1] != '\'' {
                // plain char literal 'x'
                i = q + 3;
                tokens.push(Token { kind: TokKind::Char, text: String::new(), line });
                continue;
            }
            if c == '\'' {
                // lifetime
                let start = i;
                i += 1;
                while i < n && (s[i].is_alphanumeric() || s[i] == '_') {
                    i += 1;
                }
                tokens.push(Token {
                    kind: TokKind::Lifetime,
                    text: s[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // lone `b` followed by something odd: fall through as ident
        }
        // ---- numbers --------------------------------------------------
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (s[i].is_ascii_alphanumeric() || s[i] == '_') {
                i += 1;
            }
            let mut is_float = false;
            // decimal point followed by a digit (keeps `0..n` an Int + `..`)
            if at(i, '.') && i + 1 < n && s[i + 1].is_ascii_digit() {
                is_float = true;
                i += 1;
                while i < n && (s[i].is_ascii_alphanumeric() || s[i] == '_') {
                    i += 1;
                }
            }
            let text: String = s[start..i].iter().collect();
            if text.ends_with("f32") || text.ends_with("f64") {
                is_float = true;
            }
            tokens.push(Token {
                kind: if is_float { TokKind::Float } else { TokKind::Int },
                text,
                line,
            });
            continue;
        }
        // ---- identifiers / keywords -----------------------------------
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (s[i].is_alphanumeric() || s[i] == '_') {
                i += 1;
            }
            tokens.push(Token {
                kind: TokKind::Ident,
                text: s[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // ---- operators ------------------------------------------------
        let rest_starts_with = |op: &str| {
            op.chars().enumerate().all(|(k, oc)| at(i + k, oc))
        };
        if let Some(op) = OPS3.iter().find(|op| rest_starts_with(op)) {
            tokens.push(Token { kind: TokKind::Op, text: (*op).to_string(), line });
            i += 3;
            continue;
        }
        if let Some(op) = OPS2.iter().find(|op| rest_starts_with(op)) {
            tokens.push(Token { kind: TokKind::Op, text: (*op).to_string(), line });
            i += 2;
            continue;
        }
        tokens.push(Token { kind: TokKind::Op, text: c.to_string(), line });
        i += 1;
    }

    Lexed { tokens, allows }
}

/// If position `i` starts a raw (or raw-byte) string, return the index one
/// past its end plus how many newlines it spans.
fn raw_string_end(s: &[char], i: usize) -> Option<(usize, usize)> {
    let n = s.len();
    let mut j = i;
    if j < n && s[j] == 'b' {
        j += 1;
    }
    if j >= n || s[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && s[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || s[j] != '"' {
        return None;
    }
    j += 1;
    let mut newlines = 0usize;
    while j < n {
        if s[j] == '\n' {
            newlines += 1;
            j += 1;
            continue;
        }
        if s[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && s[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return Some((j + 1 + hashes, newlines));
            }
        }
        j += 1;
    }
    Some((n, newlines))
}

/// Parse a `detlint:` directive out of one line comment, if present.
fn parse_allow(
    comment: &str,
    line: usize,
    inline: bool,
    allows: &mut Vec<AllowDirective>,
) {
    let Some(pos) = comment.find("detlint:") else {
        return;
    };
    let rest = comment[pos + "detlint:".len()..].trim_start();
    let Some(body) = rest.strip_prefix("allow(") else {
        allows.push(AllowDirective {
            line,
            inline,
            rules: Vec::new(),
            has_reason: false,
            malformed: true,
        });
        return;
    };
    let Some(close) = body.find(')') else {
        allows.push(AllowDirective {
            line,
            inline,
            rules: Vec::new(),
            has_reason: false,
            malformed: true,
        });
        return;
    };
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let reason = body[close + 1..]
        .trim_start_matches(|c: char| {
            c.is_whitespace() || c == '—' || c == '–' || c == '-' || c == ':'
        })
        .trim();
    allows.push(AllowDirective {
        line,
        inline,
        rules,
        has_reason: !reason.is_empty(),
        malformed: false,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_are_dropped() {
        let src = r##"
// HashMap in a comment
/// HashMap in a doc comment
/* block HashMap /* nested */ still comment */
let s = "HashMap<String, u32>";
let r = r#"Instant::now()"#;
let real = BTreeMap::new();
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(ids.contains(&"BTreeMap".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }").tokens;
        let lifetimes: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        let chars: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(chars.len(), 1);
    }

    #[test]
    fn float_vs_int_literals() {
        let toks = lex("let a = 1; let b = 2.0; let c = 1f32; let d = 0..9;")
            .tokens;
        let kinds: Vec<(TokKind, String)> = toks
            .into_iter()
            .filter(|t| matches!(t.kind, TokKind::Int | TokKind::Float))
            .map(|t| (t.kind, t.text))
            .collect();
        assert_eq!(
            kinds,
            vec![
                (TokKind::Int, "1".into()),
                (TokKind::Float, "2.0".into()),
                (TokKind::Float, "1f32".into()),
                (TokKind::Int, "0".into()),
                (TokKind::Int, "9".into()),
            ]
        );
    }

    #[test]
    fn lines_are_tracked_across_multiline_constructs() {
        let src = "let a = 1;\n/* c\nc\nc */\nlet b = 2;\n";
        let toks = lex(src).tokens;
        let b = toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b.line, 5);
    }

    #[test]
    fn allow_directives_are_collected() {
        let src = "\
// detlint: allow(nondet-iteration) — lookup-only, never iterated
let x = 1;
let y = 2; // detlint: allow(wallclock-in-decision, float-accum-cast) — two rules
// detlint: allow(nondet-iteration)
// detlint: allowed(whoops)
/// detlint: allow(nondet-iteration) — doc comments are prose, not directives
";
        let lexed = lex(src);
        assert_eq!(lexed.allows.len(), 4);
        assert!(!lexed.allows[0].inline && lexed.allows[0].has_reason);
        assert_eq!(lexed.allows[0].rules, vec!["nondet-iteration"]);
        assert!(lexed.allows[1].inline);
        assert_eq!(lexed.allows[1].rules.len(), 2);
        assert!(!lexed.allows[2].has_reason, "no reason text");
        assert!(lexed.allows[3].malformed, "allowed( is not allow(");
    }

    #[test]
    fn multichar_ops_lex_as_one_token() {
        let toks = lex("a::b != c..=d => e %= f").tokens;
        let ops: Vec<String> = toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Op)
            .map(|t| t.text)
            .collect();
        assert_eq!(ops, vec!["::", "!=", "..=", "=>", "%="]);
    }
}
