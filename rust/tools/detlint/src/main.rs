//! detlint driver: lint `rust/src` + `rust/benches` (or explicit paths)
//! and exit nonzero on findings. Runs as a blocking CI lane next to clippy;
//! `cargo run -p detlint` from anywhere in the workspace.

use std::path::PathBuf;

fn usage() -> ! {
    eprintln!(
        "usage: detlint [PATH…]\n\
         \n\
         Lints every .rs file under the given paths (default: the\n\
         workspace's rust/src and rust/benches) against the determinism\n\
         and concurrency invariants in docs/INVARIANTS.md.\n\
         \n\
         rules: {}\n\
         \n\
         Suppress an intentional finding in place with\n\
         `// detlint: allow(<rule>) — <reason>` on the offending line or\n\
         the line above it; the reason is mandatory.\n\
         \n\
         exit status: 0 clean · 1 findings · 2 I/O or usage error",
        detlint::RULES.join(", ")
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        usage();
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        // compiled-in workspace layout: tools/detlint → tools → rust
        let rust_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .ancestors()
            .nth(2)
            .expect("detlint lives at <workspace>/rust/tools/detlint")
            .to_path_buf();
        vec![rust_dir.join("src"), rust_dir.join("benches")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    for r in &roots {
        if !r.exists() {
            eprintln!("detlint: no such path: {}", r.display());
            std::process::exit(2);
        }
    }
    match detlint::scan_tree(&roots) {
        Ok((findings, files)) => {
            if findings.is_empty() {
                eprintln!(
                    "detlint: clean — {files} file(s), {} rule(s)",
                    detlint::RULES.len()
                );
                std::process::exit(0);
            }
            println!("{}", detlint::render(&findings));
            eprintln!(
                "detlint: {} finding(s) in {files} file(s) — see \
                 docs/INVARIANTS.md; intentional exceptions need \
                 `// detlint: allow(<rule>) — <reason>`",
                findings.len()
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("detlint: scan failed: {e}");
            std::process::exit(2);
        }
    }
}
