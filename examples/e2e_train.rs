//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains the transformer LM (`lm_small` artifacts) on the synthetic
//! two-domain corpus for several hundred steps, with SAMA reweighting the
//! pretraining pool (half of which is off-domain) against an in-domain dev
//! objective. Exercises every layer of the stack in one run:
//!
//!   L1 Pallas kernels (attention, fused Adam, adapt+perturb) →
//!   L2 jax gradients (multitask + LM losses, AOT HLO) →
//!   L3 coordinator (bilevel schedule, DDP collective, meta updates).
//!
//! Logs the loss curves to stdout + `e2e_loss.csv`, and verifies:
//!   * LM/base loss decreases substantially from its initial value,
//!   * meta (downstream) loss decreases,
//!   * SAMA's learned weights separate relevant vs irrelevant pool data.
//!
//! ```bash
//! cargo run --release --example e2e_train            # default 300 steps
//! cargo run --release --example e2e_train -- steps=600 workers=2
//! ```

use anyhow::Result;
use sama::apps::pretraining::{make_task, mwn_forward_rust, MultitaskProblem};
use sama::config::{Algo, TrainConfig};
use sama::coordinator::{self, BaseOpt, ProblemFactory, RunOptions};
use sama::runtime::{params, Arg, Runtime};
use sama::util::rng::Rng;

struct E2eFactory {
    seed: u64,
    task_seed: u64,
}

impl ProblemFactory for E2eFactory {
    fn build(
        &self,
        _rank: usize,
        _world: usize,
    ) -> Result<(
        Box<dyn sama::bilevel::BilevelProblem>,
        Vec<f32>,
        Vec<f32>,
    )> {
        let rt = Runtime::new(&Runtime::artifact_dir(), "lm_small")?;
        let mut rng = Rng::new(self.seed);
        let theta0 =
            params::init_flat(&rt.config.layout_theta, rt.config.n_theta, &mut rng);
        let mut rng_l = Rng::new(self.seed ^ 0x11AB);
        let lambda0 =
            params::init_flat(&rt.config.layout_mwn, rt.config.n_mwn, &mut rng_l);
        let seq = rt.config.model.seq_len;
        let nc = rt.config.model.n_classes;
        let t = make_task(seq, nc, self.task_seed);
        let p = MultitaskProblem::new(rt, t.ft_train, t.ft_dev, t.pool, false);
        Ok((Box::new(p), theta0, lambda0))
    }

    fn base_opt(&self) -> BaseOpt {
        BaseOpt::Adam
    }
}

fn main() -> Result<()> {
    let overrides: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig {
        model: "lm_small".into(),
        algo: Algo::Sama,
        steps: 300,
        unroll: 5,
        base_lr: 1e-3,
        meta_lr: 0.02,
        sama_alpha: 0.05,
        ..TrainConfig::default()
    };
    cfg.apply_overrides(&overrides)?;

    println!(
        "== e2e: SAMA-reweighted multitask LM training ({} steps, {} worker(s)) ==",
        cfg.steps, cfg.workers
    );
    let factory = E2eFactory { seed: cfg.seed, task_seed: 42 };
    let opts = RunOptions { eval_every: 10, ..Default::default() };
    let report = coordinator::train(&cfg, &factory, &opts)?;

    // loss curves
    let mut csv = String::from("step,base_loss,meta_loss\n");
    let base = &report.base_loss.points;
    for (i, (x, y)) in base.iter().enumerate() {
        let meta = report
            .meta_loss
            .points
            .iter()
            .rev()
            .find(|(mx, _)| mx <= x)
            .map(|(_, my)| *my)
            .unwrap_or(f64::NAN);
        csv.push_str(&format!("{x},{y},{meta}\n"));
        if i % (base.len() / 15).max(1) == 0 {
            println!("  step {x:5.0}: base {y:.4}  meta {meta:.4}");
        }
    }
    std::fs::write("e2e_loss.csv", &csv)?;
    println!("wrote e2e_loss.csv ({} rows)", base.len());

    let first = report.base_loss.points.first().map(|p| p.1).unwrap_or(0.0);
    let last = report.base_loss.tail_mean(10);
    let meta_first = report.meta_loss.points.first().map(|p| p.1).unwrap_or(0.0);
    let meta_last = report.meta_loss.tail_mean(5);
    println!(
        "base loss {first:.4} → {last:.4}; meta loss {meta_first:.4} → {meta_last:.4}; \
         throughput {:.1} samples/s",
        report.throughput()
    );

    // mechanism: learned pool weights (relevant vs irrelevant)
    let rt = Runtime::new(&Runtime::artifact_dir(), "lm_small")?;
    let t = make_task(rt.config.model.seq_len, rt.config.model.n_classes, 42);
    let batch = rt.config.model.batch;
    let mut sums = [0.0f64; 2];
    let mut counts = [0usize; 2];
    for step in 0..12 {
        let (pt_tokens, rel, _) = t.pool.batch(step, batch);
        let losses = rt
            .exec(
                "lm_losses_eval",
                &[Arg::F32(&report.final_theta), Arg::I32(&pt_tokens)],
            )?
            .remove(0);
        let unc = vec![0.0f32; batch];
        let w = mwn_forward_rust(&rt, &report.final_lambda, &losses, &unc)?;
        for i in 0..batch {
            let k = usize::from(!rel[i]);
            sums[k] += w[i] as f64;
            counts[k] += 1;
        }
    }
    let w_rel = sums[0] / counts[0].max(1) as f64;
    let w_irr = sums[1] / counts[1].max(1) as f64;
    println!("learned aux weights: relevant {w_rel:.3} vs irrelevant {w_irr:.3}");

    // e2e assertions — this example is also a system test
    assert!(last < 0.7 * first, "base loss did not drop: {first} → {last}");
    assert!(
        meta_last < meta_first,
        "meta loss did not drop: {meta_first} → {meta_last}"
    );
    println!("e2e OK: all layers compose, losses decreased.");
    Ok(())
}
