//! §4.3 driver — meta-learned data pruning vs heuristics on a dataset with
//! planted duplicates and label noise.
//!
//! ```bash
//! cargo run --release --example data_pruning -- ratio=0.3 steps=300
//! ```

use sama::apps::pruning::{self, PruneMetric};
use sama::config::{Algo, TrainConfig};
use sama::data::pruning_data::{generate, PruningSpec};

fn main() -> anyhow::Result<()> {
    let overrides: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig {
        algo: Algo::Sama,
        steps: 300,
        unroll: 2,
        base_lr: 0.05,
        meta_lr: 0.02,
        sama_alpha: 0.05,
        ..TrainConfig::default()
    };
    cfg.apply_overrides(&overrides)?;
    let ratio = cfg.extra_or::<f32>("ratio", 0.3);

    let set = generate(&PruningSpec::default(), cfg.seed);
    println!(
        "pruning set: {} samples, junk fraction {:.3} (duplicates + label noise)",
        set.data.n(),
        set.junk_frac()
    );

    let full_keep: Vec<usize> = (0..set.data.n()).collect();
    let full_acc = pruning::retrain_and_eval(&cfg, &set, &full_keep)?;
    println!("full-data accuracy: {full_acc:.4}\n");

    for metric in [PruneMetric::SamaMwn, PruneMetric::El2n, PruneMetric::Random] {
        let (scores, secs) = pruning::scores(metric, &cfg, &set)?;
        let keep = pruning::prune(&scores, ratio);
        let pruned: Vec<usize> =
            (0..set.data.n()).filter(|i| !keep.contains(i)).collect();
        let acc = pruning::retrain_and_eval(&cfg, &set, &keep)?;
        println!(
            "{:12} ratio={ratio}: acc {:.4} (rel {:.1}%), junk recall {:.3}, \
             search {secs:.1}s",
            metric.name(),
            acc,
            100.0 * acc / full_acc,
            set.junk_recall(&pruned)
        );
    }
    Ok(())
}
