//! §4.2 driver — continued pretraining as end-task-aware multitask learning:
//! Baseline vs DAPT vs TARTAN-MT vs SAMA on one synthetic two-domain task.
//!
//! ```bash
//! cargo run --release --example continued_pretraining -- steps=400
//! ```

use sama::apps::pretraining::{self, Method};
use sama::config::{Algo, TrainConfig};

fn main() -> anyhow::Result<()> {
    let overrides: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig {
        model: "lm_small".into(),
        algo: Algo::Sama,
        steps: 300,
        unroll: 5,
        base_lr: 1e-3,
        meta_lr: 0.02,
        sama_alpha: 0.05,
        ..TrainConfig::default()
    };
    cfg.apply_overrides(&overrides)?;
    let task_seed = cfg.extra_or::<u64>("task_seed", 100);

    println!("== continued pretraining (task seed {task_seed}, {} steps) ==", cfg.steps);
    for method in [Method::Baseline, Method::Dapt, Method::TartanMt, Method::Sama] {
        let out = pretraining::run(&cfg, method, task_seed)?;
        print!("{:12}: downstream acc {:.4}", method.name(), out.test_accuracy);
        if let Some((rel, irr)) = out.relevance {
            print!("  (aux weights: relevant {rel:.3} vs irrelevant {irr:.3})");
        }
        println!();
    }
    Ok(())
}
