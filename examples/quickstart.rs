//! Quickstart: the smallest end-to-end SAMA run.
//!
//! Loads the AOT artifacts, builds a simulated weak-supervision task,
//! meta-trains a reweighting network with SAMA for a few hundred steps and
//! prints test accuracy against the plain-finetune baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use sama::apps::wrench;
use sama::config::{Algo, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig {
        model: "cls_tiny".into(),
        steps: 400,
        unroll: 5,
        base_lr: 1e-3,
        meta_lr: 0.02,
        sama_alpha: 0.05,
        ..TrainConfig::default()
    };

    println!("== SAMA quickstart: noisy text classification (agnews sim) ==");

    cfg.algo = Algo::None;
    let finetune = wrench::run(&cfg, "agnews")?;
    println!(
        "finetune : test acc {:.4} ({:.0} samples/s)",
        finetune.test_accuracy,
        finetune.report.throughput()
    );

    cfg.algo = Algo::Sama;
    let sama = wrench::run(&cfg, "agnews")?;
    println!(
        "SAMA     : test acc {:.4} ({:.0} samples/s)  — weak labels were {:.4}",
        sama.test_accuracy,
        sama.report.throughput(),
        sama.weak_label_accuracy
    );
    println!(
        "meta-learned weights: clean {:.3} vs mislabeled {:.3}",
        sama.mean_weight_clean, sama.mean_weight_noisy
    );
    println!(
        "SAMA {} finetune by {:+.2} accuracy points",
        if sama.test_accuracy >= finetune.test_accuracy { "beats" } else { "trails" },
        100.0 * (sama.test_accuracy - finetune.test_accuracy)
    );
    Ok(())
}
