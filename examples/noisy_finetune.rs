//! §4.1 driver — noisy finetuning with data reweighting and label
//! correction, across algorithms and worker counts.
//!
//! ```bash
//! cargo run --release --example noisy_finetune -- dataset=trec algo=sama \
//!     meta_ops=rc workers=2 steps=800
//! ```
//! (any `key=value` accepted by [`sama::config::TrainConfig::set`]).

use sama::apps::wrench;
use sama::config::TrainConfig;

fn main() -> anyhow::Result<()> {
    let overrides: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig {
        steps: 600,
        unroll: 5,
        meta_lr: 0.02,
        sama_alpha: 0.05,
        ..TrainConfig::default()
    };
    cfg.apply_overrides(&overrides)?;
    let dataset = cfg
        .extra
        .get("dataset")
        .cloned()
        .unwrap_or_else(|| "imdb".into());

    println!(
        "noisy finetuning: dataset={dataset} algo={} ops={:?} workers={}",
        cfg.algo.name(),
        cfg.meta_ops,
        cfg.workers
    );
    let out = wrench::run(&cfg, &dataset)?;
    println!(
        "weak-label acc {:.4} → test acc {:.4}",
        out.weak_label_accuracy, out.test_accuracy
    );
    println!(
        "throughput {:.1} samples/s over {} workers; comm: {:?}",
        out.report.throughput(),
        out.report.workers,
        out.report
            .comm
            .iter()
            .map(|c| format!(
                "{:.0}MB sent, {:.2}s comm ({:.2}s blocked)",
                c.bytes_sent as f64 / 1e6,
                c.comm_seconds,
                c.blocked_seconds
            ))
            .collect::<Vec<_>>()
    );
    println!(
        "learned weights: clean {:.3} vs mislabeled {:.3}",
        out.mean_weight_clean, out.mean_weight_noisy
    );
    // loss curve tail
    let pts = &out.report.base_loss.points;
    for (x, y) in pts.iter().step_by((pts.len() / 10).max(1)) {
        println!("  step {x:5.0}: base loss {y:.4}");
    }
    Ok(())
}
